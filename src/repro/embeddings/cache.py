"""Tiered frequency-aware embedding cache with lookahead prefetch.

Production CTR tables run to hundreds of GB while an accelerator box holds
tens; the classical fix is a software-managed cache in front of each
embedding PS (DESIGN.md §11). ``CachedStore`` fronts ONE contiguous
(rows, d) table+accumulator pair — a PS shard (``embeddings/shards.py``) or
``HogwildSim``'s packed collection — with two tiers:

* a **device-resident hot tier**: a fixed-budget contiguous (H, d) pair, so
  the existing fused ``embedding_bag`` / ``sparse_adagrad`` kernels run on
  it UNCHANGED (only row ids are remapped to hot slots);
* a **host-resident cold store**: plain numpy arrays holding the canonical
  values of every non-resident row (entries for hot rows are stale until
  eviction writes them back).

A **routing table** maps every global row id to (tier, slot). Placement
state is published atomically: ``(hot arrays, routing)`` travel together in
one immutable ``TierState`` swapped under a lock, and because jnp arrays
are immutable a reader that grabbed a state keeps a self-consistent view no
matter what migrations land after — the same wholesale-swap discipline the
PS shards already use (DESIGN.md §10.3).

The cache is a **pure placement optimization**: a lookup/update routed
through the hot tier is bitwise-identical to the same kernel launch on the
full table (same row values, same per-row occurrence order — the kernels'
duplicate-accumulate sorts are stable and rows are independent), and
``merged()`` reconstructs the canonical table exactly, so checkpoints, the
sync oracle, and every consumer of the packed view are cache-invisible.
``tests/test_cache.py`` pins both properties.

``LookaheadPrefetcher`` is the BagPipe move (PAPERS.md): the training
stream is a pure function of the iteration counter, so the next K queued
batches can be *peeked* — the shadow thread (already the background worker,
PRs 1-6) computes their miss sets and stages cold->hot promotions plus
frequency-aware (decayed-LFU) evictions as batched row copies between
syncs. A cold row that beats the prefetch horizon falls back to a
synchronous host gather inside ``lookup`` — counted (``stall_lookups``),
never fatal, and never a blocked *other* trainer.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op
from repro.models.layers import Params

HOT, COLD = 0, 1


@dataclass(frozen=True)
class CacheConfig:
    """Two-tier cache policy. Exactly one of ``hot_rows`` (absolute row
    budget per store) / ``hot_frac`` (fraction of the store's rows) must be
    set. ``lookahead`` is the number of queued batches the prefetcher
    peeks (0 = no prefetch: every cold row is a counted synchronous
    stall — still exact). ``decay`` ages the LFU frequency counters once
    per prefetch round so yesterday's hot rows can leave the device."""

    hot_rows: Optional[int] = None
    hot_frac: Optional[float] = None
    lookahead: int = 2
    decay: float = 0.8
    update_retries: int = 3  # optimistic-swap retries when a migration races

    def validate(self) -> "CacheConfig":
        if (self.hot_rows is None) == (self.hot_frac is None):
            raise ValueError(
                f"exactly one of hot_rows/hot_frac must be set, got "
                f"hot_rows={self.hot_rows}, hot_frac={self.hot_frac}")
        if self.hot_rows is not None and self.hot_rows < 1:
            raise ValueError(f"hot_rows must be >= 1, got {self.hot_rows}")
        if self.hot_frac is not None and not 0.0 < self.hot_frac <= 1.0:
            raise ValueError(f"hot_frac must be in (0, 1], got {self.hot_frac}")
        if self.lookahead < 0:
            raise ValueError(f"lookahead must be >= 0, got {self.lookahead}")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.update_retries < 0:
            raise ValueError(f"update_retries must be >= 0, got {self.update_retries}")
        return self

    def resolve_hot_rows(self, n_rows: int) -> int:
        h = (
            self.hot_rows
            if self.hot_rows is not None
            else max(1, int(round(self.hot_frac * n_rows)))
        )
        return min(h, n_rows)

    def effective_lookahead(self, pipeline_depth: int = 1) -> int:
        """Prefetch horizon composed with the step pipeline (DESIGN.md §13):
        the prefetcher must peek at least as far ahead as lookups are
        staged, or every staged lookup beyond the horizon pays exactly the
        synchronous-promotion stall the pipeline was meant to hide.
        ``lookahead=0`` stays 0 — prefetch explicitly off is respected
        (staged cold rows become counted stalls, still exact)."""
        if self.lookahead == 0:
            return 0
        return max(self.lookahead, pipeline_depth)


@dataclass(frozen=True)
class Routing:
    """Immutable row -> (tier, slot) map, the atomic publish unit. ``slot``
    holds the hot-tier slot of each row (-1 = cold); ``hot_row`` is the
    inverse (-1 = free slot). ``version`` bumps only on MIGRATION — trainer
    updates swap hot arrays without touching routing, so Hogwild lost
    updates between trainers stay possible (the preserved property) while
    an update computed against a superseded placement is detected and
    retried instead of corrupting the tier."""

    slot: np.ndarray  # (n_rows,) int32, -1 = cold
    hot_row: np.ndarray  # (H,) int32, -1 = free
    version: int

    def tier(self, row: int) -> int:
        return HOT if self.slot[row] >= 0 else COLD


@dataclass(frozen=True)
class TierState:
    """What a reader needs for one consistent lookup/update: the hot arrays
    and the routing that indexes them, published together."""

    hot: Params  # {"table": (H, d), "acc": (H, d)} device arrays
    routing: Routing


@dataclass
class CacheStats:
    lookups: int = 0
    hit_rows: int = 0  # unique rows already resident at lookup
    miss_rows: int = 0  # unique rows promoted synchronously (stall path)
    stall_lookups: int = 0  # lookups that paid >= 1 synchronous promotion
    prefetch_rows: int = 0  # rows promoted ahead of need by the prefetcher
    evict_rows: int = 0
    writeback_rows: int = 0  # evictions that drained table+acc to the cold store
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    update_conflicts: int = 0  # optimistic update swaps retried after a migration
    dropped_updates: int = 0  # retries exhausted (bounded, counted — never a stall)
    staged_lookups: int = 0  # lookups dispatched ahead of need by the step pipeline

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


@dataclass
class _Plan:
    """A staged migration: decided from a snapshot WITHOUT the lock, applied
    under it (bounded row copies + one routing publish)."""

    promote: np.ndarray  # global rows to bring hot
    dst: np.ndarray  # hot slots they land in
    evict_rows: np.ndarray  # global rows leaving the hot tier (writeback)
    evict_slots: np.ndarray  # their slots (a prefix of dst)
    free_slots: np.ndarray = field(default_factory=lambda: np.empty(0, np.int32))


class CachedStore:
    """Two-tier store over one contiguous table. All row ids are LOCAL to
    this store (the caller routes shard-local ids; ``HogwildSim`` passes
    packed global ids). Thread model: ``lookup``/``update`` are called by
    trainer threads, ``prefetch`` by the background worker; every placement
    change happens under ``_lock`` and lands as a fresh ``TierState``."""

    def __init__(self, state: Params, cfg: CacheConfig, *, eps: float = 1e-8):
        self.cfg = cfg.validate()
        self.n_rows, self.dim = state["table"].shape
        self.eps = eps
        H = cfg.resolve_hot_rows(self.n_rows)
        self.hot_budget = H
        # Host-resident cold store: canonical for cold rows; hot rows'
        # entries go stale until eviction writes them back.
        # guarded-by: _lock — migrations write back evicted rows under _lock;
        # merged() copies under it too, so readers never see a torn writeback
        self.cold: Dict[str, np.ndarray] = {
            k: np.array(state[k], dtype=np.float32, copy=True) for k in state
        }
        # Initial placement: rows [0, H) hot (the data's skew concentrates
        # on low ids; the prefetcher re-derives placement within a round).
        slot = np.full(self.n_rows, -1, np.int32)
        slot[:H] = np.arange(H, dtype=np.int32)
        hot_row = np.full(H, -1, np.int32)
        hot_row[:min(H, self.n_rows)] = np.arange(min(H, self.n_rows), dtype=np.int32)
        hot = {k: jnp.asarray(self.cold[k][:H]) for k in self.cold}
        # swap-published; guarded-by-writes: _lock — every placement change
        # lands as a fresh immutable TierState; trainers read lock-free
        self._st = TierState(hot, Routing(slot, hot_row, 0))
        # hogwild-race: ok — LFU ranking signal; lost increments shift ranks only
        self.freq = np.zeros(self.n_rows, np.float64)
        # swap-published; hogwild-race: ok — prefetcher rebinds a fresh mask
        self._pinned = np.zeros(self.n_rows, bool)  # prefetch-horizon rows
        self.stats = CacheStats()  # hogwild-race: ok — diagnostic counters
        self._lock = threading.Lock()
        self._row_bytes = 4 * self.dim * len(self.cold)  # f32 table + acc

    # -- reads ---------------------------------------------------------------
    @property
    def state(self) -> TierState:
        return self._st

    def resident(self, rows: np.ndarray) -> np.ndarray:
        """Mask of ``rows`` currently in the hot tier."""
        return self._st.routing.slot[rows] >= 0

    def merged(self) -> Params:
        """The canonical full (rows, d) state — cold store overlaid with the
        live hot tier. Bitwise-exact: hot rows come straight off the device,
        cold rows were written back exactly on eviction. This is what
        snapshots, checkpoints, and ``to_packed`` consume: the cache is
        invisible above this line.

        The cold copy and the TierState capture happen atomically under the
        lock (migrations mutate ``cold`` under it); the device gathers run
        OUTSIDE it (no-blocking-under-lock, DESIGN.md §12) against the
        captured immutable TierState — the result is an exact snapshot as
        of capture time."""
        with self._lock:
            st = self._st
            out = {k: self.cold[k].copy() for k in self.cold}
        occ = st.routing.hot_row >= 0
        rows = st.routing.hot_row[occ]
        for k in out:
            out[k][rows] = np.asarray(jnp.take(st.hot[k], jnp.asarray(np.flatnonzero(occ)), axis=0))
        return {k: jnp.asarray(v) for k, v in out.items()}

    def check_invariants(self) -> None:
        """Every row routed to exactly one (tier, slot); slot<->row maps are
        mutually inverse; no slot holds two rows (tests/test_cache.py)."""
        st = self._st
        slot, hot_row = st.routing.slot, st.routing.hot_row
        hot_rows = np.flatnonzero(slot >= 0)
        if len(np.unique(slot[hot_rows])) != len(hot_rows):
            raise AssertionError("two rows share a hot slot")
        if not np.array_equal(hot_row[slot[hot_rows]], hot_rows):
            raise AssertionError("slot/hot_row maps disagree")
        occupied = np.flatnonzero(hot_row >= 0)
        if not np.array_equal(np.sort(slot[hot_row[occupied]]), np.sort(occupied)):
            raise AssertionError("occupied slot not routed back")
        if len(hot_rows) != len(occupied):
            raise AssertionError("tier population mismatch")

    # -- hot path ------------------------------------------------------------
    def lookup(self, idx: np.ndarray, *, staged: bool = False) -> jnp.ndarray:
        """Sum-pooled lookup, idx (..., m) local row ids -> (..., d). Runs
        the unchanged fused kernel over the hot tier with ids remapped to
        slots; any cold row is promoted synchronously first (the counted
        stall path — a miss that beat the prefetch horizon). ``staged``
        marks a lookup the step pipeline (core/pipeline.py) dispatched
        ahead of consumption — same semantics, separately counted."""
        idx = np.asarray(idx)
        rows, counts = np.unique(idx, return_counts=True)
        self.freq[rows] += counts
        st = self._st
        missing = rows[st.routing.slot[rows] < 0]
        self.stats.lookups += 1
        if staged:
            self.stats.staged_lookups += 1
        self.stats.hit_rows += len(rows) - len(missing)
        if len(missing):
            self.stats.miss_rows += len(missing)
            self.stats.stall_lookups += 1
        # loop, not a single promote: a concurrent prefetch can evict a row
        # that WAS resident at capture time — residency must be re-checked
        # against the exact state the kernel will read
        while len(missing):
            st = self._promote_sync(missing, keep=rows)
            missing = rows[st.routing.slot[rows] < 0]
        slots = st.routing.slot[idx]
        return embedding_bag_op(st.hot["table"], jnp.asarray(slots))

    def update(self, idx: np.ndarray, g_pooled: jnp.ndarray, lr: float) -> bool:
        """Fused row-sparse Adagrad on the hot tier: idx (..., m) local row
        ids, g_pooled (..., d). The batch's rows are already resident
        (lookup ran this batch; a direct call promotes first). The new hot
        arrays land via optimistic swap: publication fails only if a
        MIGRATION republished routing mid-kernel, in which case the update
        recomputes against the new placement (bounded retries, then a
        counted drop — trainer-vs-trainer interleaving stays lock-free and
        lossy, the preserved Hogwild property)."""
        idx = np.asarray(idx)
        rows = np.unique(idx)
        for _ in range(self.cfg.update_retries + 1):
            st = self._st
            while True:  # see lookup: re-check against the state we'll use
                missing = rows[st.routing.slot[rows] < 0]
                if not len(missing):
                    break
                st = self._promote_sync(missing, keep=rows)
            slots = st.routing.slot[idx]
            table, acc = sparse_adagrad_op(
                st.hot["table"], st.hot["acc"], jnp.asarray(slots), g_pooled,
                lr=lr, eps=self.eps)
            with self._lock:
                if self._st.routing is st.routing:
                    self._st = TierState({"table": table, "acc": acc}, st.routing)
                    return True
            self.stats.update_conflicts += 1
        self.stats.dropped_updates += 1
        return False

    # -- migration -----------------------------------------------------------
    def _plan_migration(
        self, need: np.ndarray, keep: np.ndarray, routing: Routing
    ) -> Optional[_Plan]:
        """Stage promotions for ``need`` (cold rows, deduped) evicting the
        lowest-frequency unpinned hot rows not in ``keep``. Pure decision —
        no copies, no lock."""
        need = need[routing.slot[need] < 0]
        if not len(need):
            return None
        free = np.flatnonzero(routing.hot_row < 0).astype(np.int32)
        n_evict = max(0, len(need) - len(free))
        evict_rows = np.empty(0, np.int64)
        if n_evict:
            protect = np.zeros(self.n_rows, bool)
            protect[keep] = True
            protect[need] = True
            cand = routing.hot_row[routing.hot_row >= 0]
            cand = cand[~protect[cand]]
            if len(cand) < n_evict:
                raise ValueError(
                    f"hot tier too small: need {len(need)} promotions but "
                    f"only {len(cand)} evictable of {self.hot_budget} slots "
                    f"— raise hot_rows above the per-batch working set")
            # frequency-aware (decayed-LFU) victims; prefer rows the
            # prefetch horizon has NOT pinned. lexsort is stable, so ties
            # break by row id — deterministic for the sim.
            order = np.lexsort((cand, self.freq[cand], self._pinned[cand].astype(np.int8)))
            evict_rows = cand[order[:n_evict]]
        evict_slots = routing.slot[evict_rows].astype(np.int32)
        dst = np.concatenate([free[:len(need)], evict_slots])[:len(need)]
        return _Plan(need, dst.astype(np.int32), evict_rows, evict_slots, free[:len(need)])

    # holds-lock: _lock; lock-blocking: ok — bounded row scatters; doing them
    # optimistically would break eviction-writeback-before-slot-reuse exactness
    def _apply_migration(self, plan: _Plan) -> TierState:
        """Apply a staged migration under the lock against the CURRENT state
        (which may have advanced past the one the plan was computed from —
        slots/rows are re-validated implicitly by planning from routing,
        which only this method changes). Evicted rows drain table+acc to
        the cold store BEFORE their slot is reused, so no pending Adagrad
        update is ever dropped; then promotions land as one batched
        device scatter per array and the new routing publishes atomically."""
        st = self._st
        hot = dict(st.hot)
        if len(plan.evict_rows):
            ev = jnp.asarray(plan.evict_slots)
            for k in hot:
                self.cold[k][plan.evict_rows] = np.asarray(jnp.take(hot[k], ev, axis=0))
            self.stats.evict_rows += len(plan.evict_rows)
            self.stats.writeback_rows += len(plan.evict_rows)
            self.stats.bytes_d2h += len(plan.evict_rows) * self._row_bytes
        dst = jnp.asarray(plan.dst)
        for k in hot:
            hot[k] = hot[k].at[dst].set(jnp.asarray(self.cold[k][plan.promote]))
        self.stats.bytes_h2d += len(plan.promote) * self._row_bytes
        slot = st.routing.slot.copy()
        hot_row = st.routing.hot_row.copy()
        slot[plan.evict_rows] = -1
        slot[plan.promote] = plan.dst
        hot_row[plan.dst] = plan.promote
        new = TierState(hot, Routing(slot, hot_row, st.routing.version + 1))
        self._st = new
        return new

    def _promote_sync(self, missing: np.ndarray, keep: np.ndarray) -> TierState:
        """The stall path: a cold row reached ``lookup``/``update`` before
        the prefetcher did. Promote synchronously (bounded host gather +
        one device scatter) so the fused kernel still runs over a single
        contiguous tier — exactness is never traded for speed."""
        with self._lock:
            plan = self._plan_migration(np.asarray(missing), keep, self._st.routing)
            return self._apply_migration(plan) if plan else self._st

    def prefetch(self, horizon: List[np.ndarray]) -> Dict[str, int]:
        """One background prefetch round over the peeked batches' row sets
        (earliest first). Ages the LFU counters, pins the horizon against
        eviction, promotes the misses the hot budget can take, and evicts
        cold-bound victims — all between syncs, off the training path."""
        self.freq *= self.cfg.decay
        want: List[np.ndarray] = []
        seen = np.zeros(self.n_rows, bool)
        budget = self.hot_budget
        for rows in horizon:
            if rows is None or not len(rows):
                continue
            rows = np.unique(rows)
            fresh = rows[~seen[rows]]
            take = fresh[:max(0, budget - int(seen.sum()))]
            seen[take] = True
            want.append(take)
        self._pinned = seen
        if not want:
            return {"promoted": 0}
        need = np.concatenate(want)
        with self._lock:
            routing = self._st.routing
            plan = self._plan_migration(need, need, routing)
            if plan is None:
                return {"promoted": 0}
            self._apply_migration(plan)
            self.stats.prefetch_rows += len(plan.promote)
            return {"promoted": int(len(plan.promote))}


class LookaheadPrefetcher:
    """BagPipe-style lookahead for one store: ``feed(j)`` returns the local
    row ids of the j-th QUEUED batch (0 = the next batch to train, None =
    end of stream). ``step()`` peeks the next ``cfg.lookahead`` batches and
    runs one prefetch round — the shadow thread calls it between syncs; the
    deterministic sim calls it at iteration boundaries."""

    def __init__(
        self,
        store: CachedStore,
        feed: Callable[[int], Optional[np.ndarray]],
        lookahead: Optional[int] = None,
    ):
        self.store = store
        self.feed = feed
        self.lookahead = (store.cfg.lookahead if lookahead is None else lookahead)

    def step(self) -> Dict[str, int]:
        if self.lookahead == 0:
            return {"promoted": 0}
        horizon = [self.feed(j) for j in range(self.lookahead)]
        return self.store.prefetch([r for r in horizon if r is not None])

"""Plan-sharded embedding engine: the paper's embedding parameter servers.

``plan_shards`` runs the greedy LPT planner (``table.bin_pack`` over
``table.lookup_costs``) to assign whole categorical tables to ``n_shards``
embedding PSs — the paper's load balancing (§3.1). ``ShardPlan`` freezes that
assignment plus the derived routing arrays; the packed (total_rows, dim)
collection splits into one contiguous (shard_rows, dim) array per PS, each with
its co-located Adagrad accumulator.

Lookups route by the plan: each shard answers one fused lookup+pool kernel
launch over its own features, and the pooled planes reassemble in feature
order. Backward routes the same way through the fused sparse-Adagrad scatter
kernel — one launch per shard, touching only that PS's rows.

``EmbeddingShards`` is the stateful host-side holder ``ThreadedShadowRunner``
uses: ``states[s]`` are genuinely independent per-PS Hogwild states, so
concurrent trainers writing to different PSs no longer serialize through one
jitted scatter over a single packed array (DESIGN.md §7)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.embeddings.table import (
    TableSpec,
    bin_pack,
    init_tables,
    lookup_costs,
)
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op
from repro.models.layers import Params


@dataclass(frozen=True)
class ShardPlan:
    """A frozen table->PS assignment plus the derived routing arrays."""
    spec: TableSpec
    bins: Tuple[Tuple[int, ...], ...]  # feature/table ids per shard (LPT order)
    feature_shard: Tuple[int, ...]  # (F,) shard owning each feature
    feature_local_offset: Tuple[int, ...]  # (F,) row offset inside its shard
    shard_rows: Tuple[int, ...]  # packed rows per shard

    @property
    def n_shards(self) -> int:
        return len(self.bins)

    @property
    def feature_order(self) -> Tuple[int, ...]:
        """Features in shard-concatenation order (bins flattened)."""
        return tuple(f for feats in self.bins for f in feats)


def plan_shards(spec: TableSpec, n_shards: int, batch_size: int) -> ShardPlan:
    """LPT bin-pack the tables' profiled lookup costs across the PSs."""
    n_shards = max(1, min(n_shards, len(spec.sizes)))
    bins = tuple(
        tuple(b) for b in bin_pack(lookup_costs(spec, batch_size), n_shards)
    )
    feature_shard = [0] * len(spec.sizes)
    feature_local_offset = [0] * len(spec.sizes)
    shard_rows = []
    for s, feats in enumerate(bins):
        off = 0
        for f in feats:
            feature_shard[f] = s
            feature_local_offset[f] = off
            off += spec.sizes[f]
        shard_rows.append(off)
    return ShardPlan(spec, bins, tuple(feature_shard),
                     tuple(feature_local_offset), tuple(shard_rows))


def shard_states(plan: ShardPlan, state: Params) -> List[Params]:
    """Split a packed {"table", "acc"} state into per-shard states (each shard
    concatenates its tables' global row ranges in bin order)."""
    goff = plan.spec.offsets
    out = []
    for feats in plan.bins:
        parts = [(int(goff[f]), int(goff[f]) + plan.spec.sizes[f]) for f in feats]
        out.append({
            k: jnp.concatenate([state[k][a:b] for a, b in parts])
            for k in state
        })
    return out


def packed_state(plan: ShardPlan, states: List[Params]) -> Params:
    """Inverse of ``shard_states``: reassemble the global packed state."""
    parts = {k: [None] * len(plan.spec.sizes) for k in states[0]}
    for f in range(len(plan.spec.sizes)):
        s, loff = plan.feature_shard[f], plan.feature_local_offset[f]
        for k in parts:
            parts[k][f] = states[s][k][loff:loff + plan.spec.sizes[f]]
    return {k: jnp.concatenate(v) for k, v in parts.items()}


def _route(plan: ShardPlan, s: int, idx: jnp.ndarray) -> jnp.ndarray:
    """Shard s's slice of a (B, F, m) index batch, in LOCAL row ids."""
    feats = plan.bins[s]
    loc = jnp.take(idx, jnp.asarray(feats), axis=1)
    offs = jnp.asarray([plan.feature_local_offset[f] for f in feats], jnp.int32)
    return loc + offs[None, :, None]


def shard_lookup(
    plan: ShardPlan,
    tables: Tuple[jnp.ndarray, ...],
    idx: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Plan-routed sum-pooled lookup. idx: (B, F, m) LOCAL-per-feature ids
    (as produced by the data pipeline) -> (B, F, dim). One fused kernel
    launch per shard."""
    outs = [
        embedding_bag_op(tables[s], _route(plan, s, idx),
                         use_pallas=use_pallas, interpret=interpret)
        for s in range(plan.n_shards)
    ]
    pooled = jnp.concatenate(outs, axis=1)  # features in bins order
    inv = np.argsort(np.asarray(plan.feature_order))
    return jnp.take(pooled, jnp.asarray(inv), axis=1)


def shard_update(
    plan: ShardPlan,
    s: int,
    state_s: Params,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    lr: float,
    eps: float = 1e-8,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> Params:
    """Fused sparse-Adagrad backward for ONE shard: touches only this PS's
    rows, so per-shard updates are independent Hogwild writes."""
    m, d = idx.shape[-1], g_pooled.shape[-1]
    loc = _route(plan, s, idx).reshape(-1, m)
    g = jnp.take(g_pooled, jnp.asarray(plan.bins[s]), axis=1).reshape(-1, d)
    table, acc = sparse_adagrad_op(
        state_s["table"], state_s["acc"], loc, g, lr=lr, eps=eps,
        use_pallas=use_pallas, interpret=interpret)
    return {"table": table, "acc": acc}


class EmbeddingShards:
    """Host-side holder of the per-PS Hogwild states (ThreadedShadowRunner's
    embedding substrate). ``states[s]`` is replaced wholesale per update —
    concurrent trainers can interleave per shard (lost updates included:
    that is the preserved Hogwild property, DESIGN.md §2)."""

    def __init__(self, plan: ShardPlan, states: List[Params]):
        self.plan = plan
        self.states = states

    @classmethod
    def init(cls, plan: ShardPlan, key: jax.Array) -> "EmbeddingShards":
        # Seed-identical to the single-table engine: init the packed
        # collection once, then split by the plan.
        return cls(plan, shard_states(plan, init_tables(plan.spec, key)))

    def tables(self) -> Tuple[jnp.ndarray, ...]:
        """Lock-free snapshot of the per-shard tables (Hogwild read)."""
        return tuple(st["table"] for st in self.states)

    def to_packed(self) -> Params:
        """The engine-independent packed {"table", "acc"} view."""
        return packed_state(self.plan, self.states)

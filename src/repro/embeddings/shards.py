"""Plan-sharded embedding engine: the paper's embedding parameter servers.

``plan_shards`` runs the greedy LPT planner (``table.bin_pack`` over
``table.lookup_costs``) to assign whole categorical tables to ``n_shards``
embedding PSs — the paper's load balancing (§3.1). ``ShardPlan`` freezes that
assignment plus the derived routing arrays; the packed (total_rows, dim)
collection splits into one contiguous (shard_rows, dim) array per PS, each with
its co-located Adagrad accumulator.

Lookups route by the plan: each shard answers one fused lookup+pool kernel
launch over its own features, and the pooled planes reassemble in feature
order. Backward routes the same way through the fused sparse-Adagrad scatter
kernel — one launch per shard, touching only that PS's rows.

``EmbeddingShards`` is the stateful host-side holder ``ThreadedShadowRunner``
uses: ``states[s]`` are genuinely independent per-PS Hogwild states, so
concurrent trainers writing to different PSs no longer serialize through one
jitted scatter over a single packed array (DESIGN.md §7).

Each PS is also a real *failure domain* (DESIGN.md §10.3): per-shard health
state, background snapshots, and fail/recover transitions. Because every
update replaces ``states[s]`` wholesale with fresh immutable arrays, a
snapshot is an O(1) reference grab — the shadow thread (already the
background worker) snapshots every few rounds for free. When a shard fails
(``fail_shard``, injected via ``FaultSpec.ps_fail_at``), its live state is
lost; lookups transparently fall back to the latest snapshot (a bounded-
staleness read — training on surviving shards never blocks) and updates
routed at it retry with backoff under ``ShardRetryPolicy`` and are then
*dropped* (counted — the measured staleness cost). ``recover_shard``
rehydrates the shard from its snapshot and it rejoins the routing plan.

Tiered cache (DESIGN.md §11): pass a ``CacheConfig`` and each PS fronts its
contiguous table with a ``embeddings/cache.py`` two-tier store — a device-
resident hot-row tier the unchanged fused kernels run on, a host-resident
cold store, and an atomically published routing table. Lookups go through
``cached_lookup`` (per-shard hot-tier kernel launches, bitwise-identical to
the full-table path), updates through ``cached_update`` (same health/retry/
drop ladder as ``try_update``). The cache is invisible above the canonical
view: ``snapshot_all`` and ``to_packed`` merge hot+cold back into the full
table, so PS failure, recovery, checkpoints, and the sync oracle see
exactly what they saw before — at the price that a snapshot drains the hot
tier (O(hot_rows) device reads) instead of being an O(1) reference grab."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.embeddings.cache import CacheConfig, CachedStore
from repro.embeddings.table import (
    TableSpec,
    bin_pack,
    init_tables,
    lookup_costs,
)
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op
from repro.models.layers import Params


@dataclass(frozen=True)
class ShardPlan:
    """A frozen table->PS assignment plus the derived routing arrays."""
    spec: TableSpec
    bins: Tuple[Tuple[int, ...], ...]  # feature/table ids per shard (LPT order)
    feature_shard: Tuple[int, ...]  # (F,) shard owning each feature
    feature_local_offset: Tuple[int, ...]  # (F,) row offset inside its shard
    shard_rows: Tuple[int, ...]  # packed rows per shard

    @property
    def n_shards(self) -> int:
        return len(self.bins)

    @property
    def feature_order(self) -> Tuple[int, ...]:
        """Features in shard-concatenation order (bins flattened)."""
        return tuple(f for feats in self.bins for f in feats)


def plan_shards(spec: TableSpec, n_shards: int, batch_size: int) -> ShardPlan:
    """LPT bin-pack the tables' profiled lookup costs across the PSs."""
    n_shards = max(1, min(n_shards, len(spec.sizes)))
    bins = tuple(tuple(b) for b in bin_pack(lookup_costs(spec, batch_size), n_shards))
    feature_shard = [0] * len(spec.sizes)
    feature_local_offset = [0] * len(spec.sizes)
    shard_rows = []
    for s, feats in enumerate(bins):
        off = 0
        for f in feats:
            feature_shard[f] = s
            feature_local_offset[f] = off
            off += spec.sizes[f]
        shard_rows.append(off)
    return ShardPlan(
        spec, bins, tuple(feature_shard), tuple(feature_local_offset), tuple(shard_rows)
    )


def shard_states(plan: ShardPlan, state: Params) -> List[Params]:
    """Split a packed {"table", "acc"} state into per-shard states (each shard
    concatenates its tables' global row ranges in bin order)."""
    goff = plan.spec.offsets
    out = []
    for feats in plan.bins:
        parts = [(int(goff[f]), int(goff[f]) + plan.spec.sizes[f]) for f in feats]
        out.append({k: jnp.concatenate([state[k][a:b] for a, b in parts]) for k in state})
    return out


def packed_state(plan: ShardPlan, states: List[Params]) -> Params:
    """Inverse of ``shard_states``: reassemble the global packed state."""
    parts = {k: [None] * len(plan.spec.sizes) for k in states[0]}
    for f in range(len(plan.spec.sizes)):
        s, loff = plan.feature_shard[f], plan.feature_local_offset[f]
        for k in parts:
            parts[k][f] = states[s][k][loff:loff + plan.spec.sizes[f]]
    return {k: jnp.concatenate(v) for k, v in parts.items()}


def _route(plan: ShardPlan, s: int, idx: jnp.ndarray) -> jnp.ndarray:
    """Shard s's slice of a (B, F, m) index batch, in LOCAL row ids."""
    feats = plan.bins[s]
    loc = jnp.take(idx, jnp.asarray(feats), axis=1)
    offs = jnp.asarray([plan.feature_local_offset[f] for f in feats], jnp.int32)
    return loc + offs[None, :, None]


def _route_np(plan: ShardPlan, s: int, idx: np.ndarray) -> np.ndarray:
    """Host-side ``_route``: the cache layer and the prefetcher index numpy
    routing tables, so the remap must not round-trip through the device."""
    feats = np.asarray(plan.bins[s])
    offs = np.asarray([plan.feature_local_offset[f] for f in plan.bins[s]], np.int32)
    return np.take(idx, feats, axis=1) + offs[None, :, None]


def shard_lookup(
    plan: ShardPlan,
    tables: Tuple[jnp.ndarray, ...],
    idx: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Plan-routed sum-pooled lookup. idx: (B, F, m) LOCAL-per-feature ids
    (as produced by the data pipeline) -> (B, F, dim). One fused kernel
    launch per shard."""
    outs = [
        embedding_bag_op(
            tables[s], _route(plan, s, idx), use_pallas=use_pallas, interpret=interpret
        )
        for s in range(plan.n_shards)
    ]
    pooled = jnp.concatenate(outs, axis=1)  # features in bins order
    inv = np.argsort(np.asarray(plan.feature_order))
    return jnp.take(pooled, jnp.asarray(inv), axis=1)


def shard_update(
    plan: ShardPlan,
    s: int,
    state_s: Params,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    lr: float,
    eps: float = 1e-8,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> Params:
    """Fused sparse-Adagrad backward for ONE shard: touches only this PS's
    rows, so per-shard updates are independent Hogwild writes."""
    m, d = idx.shape[-1], g_pooled.shape[-1]
    loc = _route(plan, s, idx).reshape(-1, m)
    g = jnp.take(g_pooled, jnp.asarray(plan.bins[s]), axis=1).reshape(-1, d)
    table, acc = sparse_adagrad_op(
        state_s["table"], state_s["acc"], loc, g, lr=lr, eps=eps,
        use_pallas=use_pallas, interpret=interpret)
    return {"table": table, "acc": acc}


@dataclass(frozen=True)
class ShardRetryPolicy:
    """Routing policy for updates aimed at a failed shard: retry with
    backoff inside a bounded budget, then drop (bounded staleness beats an
    unbounded stall — the surviving shards must never wait)."""

    retries: int = 2          # attempts AFTER the first
    backoff_s: float = 0.005  # sleep before each retry (doubles per retry)
    timeout_s: float = 0.05   # hard wall-clock budget for the whole attempt

    def validate(self) -> "ShardRetryPolicy":
        if self.retries < 0 or self.backoff_s < 0 or self.timeout_s <= 0:
            raise ValueError(
                f"need retries >= 0, backoff_s >= 0, timeout_s > 0; got "
                f"retries={self.retries}, backoff_s={self.backoff_s}, "
                f"timeout_s={self.timeout_s}")
        return self


@dataclass(frozen=True)
class ShardEvent:
    """One PS failure-domain transition (``EmbeddingShards.events``)."""

    kind: str  # "ps_snapshot" | "ps_fail" | "ps_recover"
    shard: int
    t: float  # time.perf_counter domain (same clock as supervision events)
    reason: str = ""


class EmbeddingShards:
    """Host-side holder of the per-PS Hogwild states (ThreadedShadowRunner's
    embedding substrate). ``states[s]`` is replaced wholesale per update —
    concurrent trainers can interleave per shard (lost updates included:
    that is the preserved Hogwild property, DESIGN.md §2).

    Failure domain (DESIGN.md §10.3): ``health[s]`` marks a live shard;
    ``fail_shard`` discards the live state (a lost PS), after which
    ``tables()`` serves the latest background snapshot for that shard (a
    stale read, counted in ``stale_lookups``) and ``try_update`` retries
    then drops writes (counted in ``dropped_updates``). ``recover_shard``
    rehydrates from the snapshot and the shard rejoins the plan.

    Thread model: trainers call ``tables``/``try_update`` lock-free (list
    reads are atomic under the GIL; states are immutable jnp arrays swapped
    wholesale); health/snapshot transitions take ``_lock``. ``init`` seeds
    generation-0 snapshots, so recovery is always possible.

    Cached mode (``cache`` set, DESIGN.md §11): each healthy shard is
    fronted by a ``CachedStore`` and the hot path moves to
    ``cached_lookup``/``cached_update`` (``tables``/``try_update`` raise —
    mixing the two views would fork the shard state). Everything above the
    hot path is unchanged: snapshots, failure, recovery, and ``to_packed``
    all go through the store's ``merged()`` canonical view, so the failure
    domain and checkpoints cannot tell the cache exists."""

    def __init__(
        self,
        plan: ShardPlan,
        states: List[Params],
        retry: Optional[ShardRetryPolicy] = None,
        cache: Optional[CacheConfig] = None,
    ):
        self.plan = plan
        self.retry = (retry or ShardRetryPolicy()).validate()
        self.cache = cache.validate() if cache is not None else None
        n = plan.n_shards
        self.health: List[bool] = [True] * n  # guarded-by-writes: _lock
        # snapshots are reference grabs of the immutable per-shard states —
        # O(1), taken by the background worker (see snapshot_all). In cached
        # mode a snapshot instead drains the hot tier (merged(), O(hot_rows)).
        # swap-published: elements; guarded-by-writes: _lock
        self.snapshots: List[Params] = list(states)
        self.snapshot_t: List[float] = [time.perf_counter()] * n  # guarded-by-writes: _lock
        # hogwild-race: ok — lossy-by-design failure counters (under-count only)
        self.dropped_updates: List[int] = [0] * n
        self.stale_lookups: List[int] = [0] * n  # hogwild-race: ok — same lossy contract
        self.events: List[ShardEvent] = []  # guarded-by-writes: _lock
        self.failed_at: Dict[int, float] = {}  # guarded-by-writes: _lock — shard -> fail time
        # per-shard failure-domain incarnation: bumped on BOTH fail and
        # recover, so a lookup staged ahead of need (core/pipeline.py) can
        # detect ANY transition between dispatch and consumption and drain
        self.incarnations: List[int] = [0] * n  # guarded-by-writes: _lock
        self._lock = threading.Lock()
        if self.cache is None:
            # swap-published: elements; hogwild-race: ok — lock-free Hogwild
            # element swap with post-dispatch health re-check (try_update)
            self.states: List[Optional[Params]] = list(states)
            # swap-published: elements; guarded-by-writes: _lock — whole-store
            # incarnations swapped on fail/recover; lock-free reads
            self.stores: List[Optional[CachedStore]] = [None] * n
        else:
            # The stores OWN the live values; states[] stays None so any
            # uncached-path access fails loudly instead of reading a fork.
            self.states = [None] * n
            self.stores = [CachedStore(st, self.cache) for st in states]

    @classmethod
    def init(
        cls,
        plan: ShardPlan,
        key: jax.Array,
        retry: Optional[ShardRetryPolicy] = None,
        cache: Optional[CacheConfig] = None,
    ) -> "EmbeddingShards":
        # Seed-identical to the single-table engine: init the packed
        # collection once, then split by the plan.
        return cls(plan, shard_states(plan, init_tables(plan.spec, key)), retry=retry, cache=cache)

    # -- hot-path routing ----------------------------------------------------
    def tables(self) -> Tuple[jnp.ndarray, ...]:
        """Lock-free snapshot of the per-shard tables (Hogwild read). A
        failed shard serves its latest background snapshot — a bounded-
        staleness read instead of a blocked trainer."""
        if self.cache is not None:
            raise RuntimeError(
                "cached mode: use cached_lookup (tables() would read the "
                "stale full-table copy, not the live hot tier)")
        out = []
        for s in range(self.plan.n_shards):
            st = self.states[s]
            # health is the authority, not just ``states[s] is None``: an
            # in-flight try_update that started before fail_shard can land
            # its swap just after, leaving a non-None state on a dead shard
            if st is None or not self.health[s]:
                st = self.snapshots[s]
                self.stale_lookups[s] += 1
            out.append(st["table"])
        return tuple(out)

    def try_update(self, s: int, fn, *args) -> bool:
        """Route one Hogwild write at shard ``s``: ``fn(state, *args)`` maps
        the current state to the new one. Against a healthy shard this is
        the plain lock-free swap. Against a failed shard it retries with
        exponential backoff inside ``ShardRetryPolicy``'s budget, then drops
        the update (returns False; the drop is the measured staleness cost —
        a trainer must never block unboundedly on a dead PS)."""
        if self.cache is not None:
            raise RuntimeError(
                "cached mode: use cached_update (try_update would write the "
                "stale full-table copy, not the live hot tier)")
        retry = self.retry
        deadline = time.perf_counter() + retry.timeout_s
        backoff = retry.backoff_s
        for attempt in range(retry.retries + 1):
            st = self.states[s]
            if self.health[s] and st is not None:
                new = fn(st, *args)
                # re-check AFTER the (milliseconds-long) kernel dispatch:
                # if the shard died mid-flight, landing the swap would
                # resurrect a non-None state on a dead PS — that write is
                # lost with the shard, exactly like a drop
                if self.health[s]:
                    self.states[s] = new
                    return True
            if attempt == retry.retries or time.perf_counter() >= deadline:
                break
            time.sleep(min(backoff, max(deadline - time.perf_counter(), 0.0)))
            backoff *= 2.0
        self.dropped_updates[s] += 1
        return False

    # -- per-shard staged lookup entry points (DESIGN.md §11/§13) ------------
    def incarnation(self, s: int) -> int:
        """Failure-domain token for shard ``s`` — the step pipeline captures
        it at staging and drains the staged value on any mismatch."""
        return self.incarnations[s]

    def lookup_shard(self, s: int, idx: np.ndarray, *, staged: bool = False) -> jnp.ndarray:
        """ONE shard's pooled plane for the full (B, F, m) batch — the
        per-shard half of ``cached_lookup``/``shard_lookup``, independently
        callable so the step pipeline (core/pipeline.py) can stage single
        shards ahead of consumption. A healthy cached shard answers from
        its hot tier, a healthy uncached shard from the live Hogwild state,
        and a failed shard from its snapshot's full table (the bounded-
        staleness read, counted in ``stale_lookups``)."""
        idx = np.asarray(idx)
        if self.cache is not None:
            store = self.stores[s]
            if store is not None and self.health[s]:
                return store.lookup(_route_np(self.plan, s, idx), staged=staged)
        else:
            st = self.states[s]
            # health is the authority, not just None-ness (see tables())
            if st is not None and self.health[s]:
                return embedding_bag_op(st["table"], _route(self.plan, s, jnp.asarray(idx)))
        self.stale_lookups[s] += 1
        return embedding_bag_op(self.snapshots[s]["table"], _route(self.plan, s, jnp.asarray(idx)))

    def assemble(self, outs: List[jnp.ndarray]) -> jnp.ndarray:
        """Reassemble the per-shard pooled planes (bins order) into the
        (B, F, dim) feature-order result — the concat half of the lookup,
        split out so staged and serial shard planes compose freely."""
        pooled = jnp.concatenate(outs, axis=1)  # features in bins order
        inv = np.argsort(np.asarray(self.plan.feature_order))
        return jnp.take(pooled, jnp.asarray(inv), axis=1)

    # -- cached hot path (DESIGN.md §11) -------------------------------------
    def cached_lookup(self, idx: np.ndarray) -> jnp.ndarray:
        """Plan-routed sum-pooled lookup through the per-shard tiered
        caches: idx (B, F, m) LOCAL-per-feature ids -> (B, F, dim), the
        exact ``shard_lookup`` contract (bitwise, tests/test_cache.py). One
        fused hot-tier launch per healthy shard; a failed shard answers
        from its snapshot's full table (the same bounded-staleness read as
        ``tables()``, counted in ``stale_lookups``)."""
        if self.cache is None:
            raise RuntimeError("cached_lookup requires cache= at init")
        idx = np.asarray(idx)
        return self.assemble([self.lookup_shard(s, idx) for s in range(self.plan.n_shards)])

    def cached_update(
        self, s: int, idx: np.ndarray, g_pooled: jnp.ndarray, lr: float, eps: float = 1e-8
    ) -> bool:
        """Route one Hogwild write at shard ``s`` through its tiered cache:
        same health ladder as ``try_update`` (retry with backoff against a
        failed shard, then a counted drop), with the inner write landing on
        the hot tier via the store's optimistic swap. idx is the full
        (B, F, m) batch; this routes shard ``s``'s features and gradient
        planes exactly like ``shard_update``."""
        if self.cache is None:
            raise RuntimeError("cached_update requires cache= at init")
        idx = np.asarray(idx)
        m, d = idx.shape[-1], g_pooled.shape[-1]
        loc = _route_np(self.plan, s, idx).reshape(-1, m)
        g = jnp.take(g_pooled, jnp.asarray(self.plan.bins[s]), axis=1).reshape(-1, d)
        retry = self.retry
        deadline = time.perf_counter() + retry.timeout_s
        backoff = retry.backoff_s
        for attempt in range(retry.retries + 1):
            store = self.stores[s]
            if self.health[s] and store is not None:
                # the store's own bounded retry handles migration races; a
                # False here is already counted in its dropped_updates
                return store.update(loc, g, lr)
            if attempt == retry.retries or time.perf_counter() >= deadline:
                break
            time.sleep(min(backoff, max(deadline - time.perf_counter(), 0.0)))
            backoff *= 2.0
        self.dropped_updates[s] += 1
        return False

    def cache_stats(self) -> Dict[str, int]:
        """Summed ``CacheStats`` across the live per-shard stores."""
        total: Dict[str, int] = {}
        for store in self.stores:
            if store is None:
                continue
            for k, v in store.stats.as_dict().items():
                total[k] = total.get(k, 0) + v
        return total

    # -- failure-domain transitions ------------------------------------------
    def snapshot_all(self, reason: str = "") -> None:
        """Background snapshot of every healthy shard (reference grabs of
        the immutable states — O(n_shards), no copies). The shadow thread
        calls this every few rounds; the snapshot is what a failed shard
        serves and what recovery rehydrates from.

        Cached mode: the snapshot is ``stores[s].merged()`` — hot+cold
        folded back into the canonical table, so recovery and checkpoints
        stay cache-invisible. That costs O(hot_rows) device reads per shard
        instead of an O(1) reference grab; still the background worker's
        bill, never a trainer's."""
        now = time.perf_counter()
        if self.cache is not None:
            # Capture the live store refs under the lock; fold hot+cold
            # OUTSIDE it (merged() is device work — no-blocking-under-lock,
            # DESIGN.md §12); publish each snapshot only if the same store
            # incarnation is still live (a fail/recover mid-merge would
            # make it a snapshot of a dead incarnation).
            with self._lock:
                live = [(s, self.stores[s])
                        for s in range(self.plan.n_shards)
                        if self.health[s] and self.stores[s] is not None]
            for s, store in live:
                snap = store.merged()
                with self._lock:
                    if self.health[s] and self.stores[s] is store:
                        self.snapshots[s] = snap
                        self.snapshot_t[s] = now
            return
        with self._lock:
            for s in range(self.plan.n_shards):
                st = self.states[s]
                if self.health[s] and st is not None:
                    self.snapshots[s] = st
                    self.snapshot_t[s] = now

    def fail_shard(self, s: int, reason: str = "") -> None:
        """PS ``s`` dies: its live state is LOST (not quietly kept). Lookups
        fall back to the snapshot, updates start dropping after retries."""
        with self._lock:
            if not self.health[s]:
                return  # already down
            self.health[s] = False
            self.states[s] = None
            self.stores[s] = None  # cached mode: both tiers die with the PS
            self.incarnations[s] += 1  # drain any in-flight staged lookups
            self.failed_at[s] = time.perf_counter()
            self.events.append(ShardEvent("ps_fail", s, self.failed_at[s], reason))

    def recover_shard(self, s: int, reason: str = "") -> None:
        """Rehydrate shard ``s`` from its latest snapshot and rejoin the
        routing plan. The delta between the snapshot and the pre-failure
        live state (plus the updates dropped while down) is the bounded-
        staleness cost the bench measures."""
        with self._lock:
            if self.health[s]:
                return  # already up
            snap = self.snapshots[s]
        store = None
        if self.cache is not None:
            # rebuild the tiered store from the canonical snapshot — a
            # background cache-warm migration (placement restarts from the
            # default; the prefetcher re-derives it within a round). The
            # build moves whole tables host->device, so it runs OUTSIDE
            # the lock; a down shard's snapshot cannot advance meanwhile.
            store = CachedStore(snap, self.cache)
        with self._lock:
            if self.health[s]:
                return  # a concurrent recovery beat us to it
            if self.cache is not None:
                self.stores[s] = store
            else:
                self.states[s] = self.snapshots[s]
            self.health[s] = True
            self.incarnations[s] += 1  # staged-during-outage lookups drain
            self.failed_at.pop(s, None)
            self.events.append(ShardEvent("ps_recover", s, time.perf_counter(), reason))

    def down_shards(self) -> List[int]:
        return [s for s in range(self.plan.n_shards) if not self.health[s]]

    def to_packed(self) -> Params:
        """The engine-independent packed {"table", "acc"} view. A failed
        shard contributes its snapshot (the best surviving copy). Cached
        shards contribute ``merged()`` — the cache-invisibility contract:
        checkpoints and the sync oracle see the canonical full tables."""
        if self.cache is not None:
            states = [
                store.merged() if store is not None else self.snapshots[s]
                for s, store in enumerate(self.stores)
            ]
        else:
            states = [
                st if st is not None else self.snapshots[s] for s, st in enumerate(self.states)
            ]
        return packed_state(self.plan, states)

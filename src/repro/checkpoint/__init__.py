"""Checkpointing: pytree save/restore on npz + a JSON manifest.

Supports the full training state (dense replicas, embedding shards, optimizer
state, opaque sync-algorithm state, step counter) so a ShadowSync run can
resume mid-stream — the one-pass constraint makes resumability a hard
requirement in production.

Crash safety (DESIGN.md §10.4): ``save`` is atomic against the failure the
supervision PR injects everywhere else — a process dying mid-write. Each save
lands as a new *generation* directory ``<path>/gen-NNNNNN``: the arrays and
manifest are written to a hidden temp directory, fsynced leaf-by-leaf, and
published with a single ``os.replace`` — a reader never observes a torn
generation. The manifest records a CRC32 per stored array; ``restore``
verifies every leaf it loads and, when bit-rot or truncation is detected,
falls back to the newest *intact* generation (``save`` keeps the last
``keep`` of them) with a warning naming the corrupt leaf. Only when every
generation is corrupt does restore raise — again naming the first corrupt
leaf, so the operator knows *what* died, not just that something did. The
pre-PR-6 flat layout (``<path>/manifest.json``) still restores.

Elastic restore (DESIGN.md §8.5): ``restore_elastic`` resizes leaves whose
shapes differ ONLY in the leading (replica) axis, so a run saved at ``R=4``
can resume at ``R=6`` — the runner then bootstraps each genuinely new slot
through ``SyncAlgorithm.on_join`` (see ``HogwildSim.load_state``); the
mean-fill here is only the placeholder those hooks overwrite.
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
import zipfile
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_GEN_PREFIX = "gen-"


class CheckpointCorruptError(ValueError):
    """A checkpoint generation failed integrity verification (CRC32 mismatch,
    truncated archive, unreadable manifest). Distinct from the plain
    ``ValueError`` a template/shape mismatch raises, because ONLY corruption
    may trigger fallback to an older generation — falling back on a shape
    mismatch would mask a caller bug with stale weights."""


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# -- generation layout --------------------------------------------------------

def _gen_dirs(path: str) -> List[Tuple[int, str]]:
    """(generation, dir) pairs under ``path``, newest first."""
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        if not name.startswith(_GEN_PREFIX):
            continue
        try:
            g = int(name[len(_GEN_PREFIX):])
        except ValueError:
            continue
        out.append((g, os.path.join(path, name)))
    return sorted(out, reverse=True)


def _read_candidates(path: str) -> List[str]:
    """Checkpoint dirs to try, newest generation first. The legacy flat
    layout (manifest directly under ``path``) is the final fallback."""
    cands = [d for _, d in _gen_dirs(path)]
    if os.path.exists(os.path.join(path, "manifest.json")):
        cands.append(path)
    if not cands:
        raise FileNotFoundError(
            f"no checkpoint at {path!r}: neither {_GEN_PREFIX}* generations "
            f"nor a flat manifest.json")
    return cands


def _fsync_file(fp: str) -> None:
    with open(fp, "rb") as f:
        os.fsync(f.fileno())


def _fsync_dir(d: str) -> None:
    fd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None,
         *, keep: int = 2) -> None:
    """Crash-safe generational save (see module docstring): temp dir ->
    fsync -> one atomic ``os.replace`` publish; the last ``keep``
    generations are retained as corruption fallbacks."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    os.makedirs(path, exist_ok=True)
    gens = _gen_dirs(path)
    next_gen = gens[0][0] + 1 if gens else 0
    final = os.path.join(path, f"{_GEN_PREFIX}{next_gen:06d}")
    tmp = os.path.join(path, f".tmp-{_GEN_PREFIX}{next_gen:06d}")
    if os.path.exists(tmp):  # debris of a previous crashed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    # bf16 isn't npz-native: store raw bits + dtype tag.
    arrays, dtypes, crcs = {}, {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
        # integrity is checked on the STORED bytes (post bf16 view)
        crcs[k] = zlib.crc32(np.ascontiguousarray(arrays[k]).tobytes())
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "dtypes": dtypes,
                   "crc32": crcs, "metadata": metadata or {}}, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_file(os.path.join(tmp, "arrays.npz"))
    _fsync_dir(tmp)
    # the publish: a crash before this line leaves only an ignored .tmp-*;
    # a crash after it leaves a fully durable generation
    os.replace(tmp, final)
    _fsync_dir(path)
    for _, old in _gen_dirs(path)[keep:]:
        shutil.rmtree(old, ignore_errors=True)


def generations(path: str) -> List[str]:
    """Generation directories under ``path``, newest first (observability +
    tests; empty for a legacy flat checkpoint)."""
    return [d for _, d in _gen_dirs(path)]


# -- reading ------------------------------------------------------------------

def _open_gen(d: str) -> Tuple[Any, Dict[str, Any]]:
    """Load (npz handle, manifest) for one generation, mapping every
    truncation/unreadable-archive failure to ``CheckpointCorruptError``."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
    except (json.JSONDecodeError, zipfile.BadZipFile, EOFError,
            OSError) as e:
        raise CheckpointCorruptError(
            f"checkpoint generation at {d!r} is unreadable "
            f"({type(e).__name__}: {e})") from e
    return data, manifest


def _load_leaf(data, manifest, key: str, path: str) -> np.ndarray:
    if key not in data.files:
        have = ", ".join(sorted(data.files)[:8])
        raise ValueError(
            f"checkpoint at {path!r} has no leaf {key!r} required by the "
            f"restore template (checkpoint leaves include: {have}"
            f"{', ...' if len(data.files) > 8 else ''})")
    try:
        arr = data[key]
    except (zipfile.BadZipFile, zlib.error, EOFError, OSError) as e:
        raise CheckpointCorruptError(
            f"corrupt leaf {key!r} in checkpoint at {path!r}: undecodable "
            f"({type(e).__name__}: {e})") from e
    want_crc = manifest.get("crc32", {}).get(key)  # legacy manifests: absent
    if want_crc is not None:
        got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if got != want_crc:
            raise CheckpointCorruptError(
                f"corrupt leaf {key!r} in checkpoint at {path!r}: crc32 "
                f"mismatch (manifest {want_crc:#010x}, stored bytes "
                f"{got:#010x})")
    if manifest["dtypes"].get(key) == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def _with_fallback(path: str, fn: Callable[[str], Any]) -> Any:
    """Run ``fn(gen_dir)`` against the newest generation, falling back to
    older intact generations ONLY on ``CheckpointCorruptError``."""
    cands = _read_candidates(path)
    first_err: Optional[CheckpointCorruptError] = None
    for i, d in enumerate(cands):
        try:
            return fn(d)
        except CheckpointCorruptError as e:
            first_err = first_err or e
            if i + 1 < len(cands):
                warnings.warn(
                    f"{e}; falling back to older generation "
                    f"{cands[i + 1]!r}", RuntimeWarning)
    raise CheckpointCorruptError(
        f"every generation of the checkpoint at {path!r} is corrupt; "
        f"first failure: {first_err}") from first_err


def read_metadata(path: str) -> Dict[str, Any]:
    """The manifest metadata alone — cheap pre-flight checks (engine/algo
    compatibility) before any array is loaded."""
    return _with_fallback(path, lambda d: _open_gen(d)[1]["metadata"])


def restore(path: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes/dtypes must match).

    Every loaded leaf is CRC-verified; a corrupt generation falls back to
    the newest intact one (warning names the corrupt leaf). Raises
    ``ValueError`` naming the offending leaf when a leaf is missing from the
    checkpoint or its shape disagrees with the template, and
    ``CheckpointCorruptError`` when no intact generation remains.
    """
    return _with_fallback(path, lambda d: _restore_one(d, like))


def _restore_one(d: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    data, manifest = _open_gen(d)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like:
        key = _SEP.join(_key_str(p) for p in pathk)
        arr = _load_leaf(data, manifest, key, d)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch restoring leaf {key!r} from {d!r}: "
                f"checkpoint has {tuple(arr.shape)}, template expects "
                f"{tuple(leaf.shape)} (use restore_elastic for replica-axis "
                f"resizes)")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def _resize_leading(arr: np.ndarray, target: int) -> np.ndarray:
    """Truncate or mean-fill the leading axis to ``target`` rows. The fill is
    a bootstrap placeholder — callers re-initialize genuinely new replica
    slots through ``SyncAlgorithm.on_join``."""
    if target <= arr.shape[0]:
        return arr[:target]
    mean = np.asarray(arr, np.float32).mean(axis=0, keepdims=True)
    fill = np.broadcast_to(mean, (target - arr.shape[0],) + arr.shape[1:])
    return np.concatenate([arr, fill.astype(arr.dtype)], axis=0)


def restore_elastic(path: str, like: Any, *,
                    may_resize: Optional[Callable[[str], bool]] = None
                    ) -> Tuple[Any, Dict[str, Any], Dict[str, Tuple]]:
    """Like ``restore``, but leaves whose shapes differ ONLY in the leading
    (replica) axis are elastically resized: shrink truncates, growth fills
    the new rows with the mean of the saved replicas. Any other shape
    mismatch still raises ``ValueError``. Returns
    ``(tree, metadata, resized)`` where ``resized`` maps each resized leaf
    key to ``(saved_shape, restored_shape)``.

    ``may_resize(key)`` restricts WHICH leaves are allowed to resize —
    callers that know where the replica axis lives should pass it so a
    leading-axis mismatch on a non-replica leaf (e.g. an embedding table
    whose row count changed between configs) raises instead of being
    silently mean-filled (see ``HogwildSim.load_state``). ``None`` permits
    every leaf.
    """
    return _with_fallback(
        path, lambda d: _restore_elastic_one(d, like, may_resize))


def _restore_elastic_one(d: str, like: Any,
                         may_resize: Optional[Callable[[str], bool]]
                         ) -> Tuple[Any, Dict[str, Any], Dict[str, Tuple]]:
    data, manifest = _open_gen(d)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves, resized = [], {}
    for pathk, leaf in flat_like:
        key = _SEP.join(_key_str(p) for p in pathk)
        arr = _load_leaf(data, manifest, key, d)
        want = tuple(leaf.shape)
        if arr.shape != want:
            allowed = may_resize is None or may_resize(key)
            elastic_ok = (allowed and arr.ndim == len(want) and arr.ndim >= 1
                          and arr.shape[1:] == want[1:])
            if not elastic_ok:
                raise ValueError(
                    f"shape mismatch restoring leaf {key!r} from {d!r}: "
                    f"checkpoint has {tuple(arr.shape)}, template expects "
                    f"{want}; only the leading (replica) axis of a "
                    f"replica-stacked leaf may differ")
            resized[key] = (tuple(arr.shape), want)
            arr = _resize_leading(arr, want[0])
        leaves.append(jnp.asarray(arr))
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["metadata"], resized)

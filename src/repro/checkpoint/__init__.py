"""Checkpointing: pytree save/restore on npz + a JSON manifest.

Supports the full training state (dense replicas, embedding shards, optimizer
state, sync-PS copy, step counter) so a ShadowSync run can resume mid-stream —
the one-pass constraint makes resumability a hard requirement in production.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    # bf16 isn't npz-native: store raw bits + dtype tag.
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(
            {"treedef": str(treedef), "dtypes": dtypes, "metadata": metadata or {}}, f
        )


def restore(path: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like:
        key = _SEP.join(_key_str(p) for p in pathk)
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]

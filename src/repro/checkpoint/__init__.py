"""Checkpointing: pytree save/restore on npz + a JSON manifest.

Supports the full training state (dense replicas, embedding shards, optimizer
state, opaque sync-algorithm state, step counter) so a ShadowSync run can
resume mid-stream — the one-pass constraint makes resumability a hard
requirement in production.

Elastic restore (DESIGN.md §8.5): ``restore_elastic`` resizes leaves whose
shapes differ ONLY in the leading (replica) axis, so a run saved at ``R=4``
can resume at ``R=6`` — the runner then bootstraps each genuinely new slot
through ``SyncAlgorithm.on_join`` (see ``HogwildSim.load_state``); the
mean-fill here is only the placeholder those hooks overwrite.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_key_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    # bf16 isn't npz-native: store raw bits + dtype tag.
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(
            {"treedef": str(treedef), "dtypes": dtypes, "metadata": metadata or {}}, f
        )


def read_metadata(path: str) -> Dict[str, Any]:
    """The manifest metadata alone — cheap pre-flight checks (engine/algo
    compatibility) before any array is loaded."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


def _load_leaf(data, manifest, key: str, path: str) -> np.ndarray:
    if key not in data.files:
        have = ", ".join(sorted(data.files)[:8])
        raise ValueError(
            f"checkpoint at {path!r} has no leaf {key!r} required by the "
            f"restore template (checkpoint leaves include: {have}"
            f"{', ...' if len(data.files) > 8 else ''})")
    arr = data[key]
    if manifest["dtypes"].get(key) == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def restore(path: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes/dtypes must match).

    Raises ``ValueError`` naming the offending leaf when a leaf is missing
    from the checkpoint or its shape disagrees with the template.
    """
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like:
        key = _SEP.join(_key_str(p) for p in pathk)
        arr = _load_leaf(data, manifest, key, path)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch restoring leaf {key!r} from {path!r}: "
                f"checkpoint has {tuple(arr.shape)}, template expects "
                f"{tuple(leaf.shape)} (use restore_elastic for replica-axis "
                f"resizes)")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def _resize_leading(arr: np.ndarray, target: int) -> np.ndarray:
    """Truncate or mean-fill the leading axis to ``target`` rows. The fill is
    a bootstrap placeholder — callers re-initialize genuinely new replica
    slots through ``SyncAlgorithm.on_join``."""
    if target <= arr.shape[0]:
        return arr[:target]
    mean = np.asarray(arr, np.float32).mean(axis=0, keepdims=True)
    fill = np.broadcast_to(mean, (target - arr.shape[0],) + arr.shape[1:])
    return np.concatenate([arr, fill.astype(arr.dtype)], axis=0)


def restore_elastic(path: str, like: Any, *,
                    may_resize: Optional[Callable[[str], bool]] = None
                    ) -> Tuple[Any, Dict[str, Any], Dict[str, Tuple]]:
    """Like ``restore``, but leaves whose shapes differ ONLY in the leading
    (replica) axis are elastically resized: shrink truncates, growth fills
    the new rows with the mean of the saved replicas. Any other shape
    mismatch still raises ``ValueError``. Returns
    ``(tree, metadata, resized)`` where ``resized`` maps each resized leaf
    key to ``(saved_shape, restored_shape)``.

    ``may_resize(key)`` restricts WHICH leaves are allowed to resize —
    callers that know where the replica axis lives should pass it so a
    leading-axis mismatch on a non-replica leaf (e.g. an embedding table
    whose row count changed between configs) raises instead of being
    silently mean-filled (see ``HogwildSim.load_state``). ``None`` permits
    every leaf.
    """
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves, resized = [], {}
    for pathk, leaf in flat_like:
        key = _SEP.join(_key_str(p) for p in pathk)
        arr = _load_leaf(data, manifest, key, path)
        want = tuple(leaf.shape)
        if arr.shape != want:
            allowed = may_resize is None or may_resize(key)
            elastic_ok = (allowed and arr.ndim == len(want) and arr.ndim >= 1
                          and arr.shape[1:] == want[1:])
            if not elastic_ok:
                raise ValueError(
                    f"shape mismatch restoring leaf {key!r} from {path!r}: "
                    f"checkpoint has {tuple(arr.shape)}, template expects "
                    f"{want}; only the leading (replica) axis of a "
                    f"replica-stacked leaf may differ")
            resized[key] = (tuple(arr.shape), want)
            arr = _resize_leading(arr, want[0])
        leaves.append(jnp.asarray(arr))
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["metadata"], resized)

"""Optimizers built from scratch (no optax dependency).

Each optimizer is an (init, update) pair over arbitrary pytrees, optax-style:
``state = opt.init(params); params, state = opt.update(params, state, grads)``.
The paper's embedding PSs use Adagrad with co-located accumulators (handled
separately in embeddings/table.py as a fused sparse update); the dense trainer
replicas use any of these.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]
    name: str = "opt"


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(params, state, grads):
        return _tmap(lambda p, g: p - (lr * g).astype(p.dtype), params, grads), state

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    def update(params, state, grads):
        new_v = _tmap(lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        if nesterov:
            step = _tmap(lambda v, g: beta * v + g.astype(jnp.float32), new_v, grads)
        else:
            step = new_v
        new_p = _tmap(lambda p, s: p - (lr * s).astype(p.dtype), params, step)
        return new_p, new_v

    return Optimizer(init, update, "momentum")


def adagrad(lr: float, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    def update(params, state, grads):
        new_acc = _tmap(lambda a, g: a + jnp.square(g.astype(jnp.float32)), state, grads)
        new_p = _tmap(
            lambda p, a, g: p - (lr * g.astype(jnp.float32) * jax.lax.rsqrt(a + eps)).astype(p.dtype),
            params, new_acc, grads,
        )
        return new_p, new_acc

    return Optimizer(init, update, "adagrad")


def rmsprop(lr: float, decay: float = 0.99, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    def update(params, state, grads):
        new_s = _tmap(
            lambda s, g: decay * s + (1 - decay) * jnp.square(g.astype(jnp.float32)),
            state, grads,
        )
        new_p = _tmap(
            lambda p, s, g: p - (lr * g.astype(jnp.float32) * jax.lax.rsqrt(s + eps)).astype(p.dtype),
            params, new_s, grads,
        )
        return new_p, new_s

    return Optimizer(init, update, "rmsprop")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": _tmap(zeros, params), "v": _tmap(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, state, grads):
        t = state["t"] + 1
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / c1) * jax.lax.rsqrt(v_ / c2 + eps * eps)  # ~adamw form
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return p - (lr * upd).astype(p.dtype)

        return _tmap(step, params, m, v), {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adam")


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Warmup-Stable-Decay schedule (MiniCPM [arXiv:2404.06395])."""

    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        return jnp.where(step < warmup + stable, warm, peak_lr * (1.0 - frac) + 0.1 * peak_lr * frac)

    return lr_at


REGISTRY = {"sgd": sgd, "momentum": momentum, "adagrad": adagrad, "rmsprop": rmsprop, "adam": adam}


def make(name: str, lr: float, **kw) -> Optimizer:
    return REGISTRY[name](lr, **kw)
